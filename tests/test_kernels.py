"""Pallas kernels: shape/dtype sweeps asserting allclose vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.semiring_spmm import counting_spmm as raw_counting
from repro.kernels.semiring_spmm import minplus_spmv as raw_minplus

RNG = np.random.default_rng(0)
INF = 1e9


# ---------------------------------------------------------------------------
# semiring kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 128, 200, 384])
def test_minplus_sweep(n):
    adj_m = (RNG.random((n, n)) < 0.05)
    adj = np.where(adj_m, 1.0, INF).astype(np.float32)
    dist = np.where(RNG.random(n) < 0.2, RNG.integers(0, 5, n), INF).astype(
        np.float32)
    got = ops.minplus_spmv(jnp.array(adj), jnp.array(dist), inf=INF)
    want = ref.minplus_spmv_ref(jnp.array(adj), jnp.array(dist), INF)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("n,q", [(128, 128), (256, 64), (200, 40), (64, 8)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_counting_sweep(n, q, dtype):
    adj = (RNG.random((n, n)) < 0.05).astype(np.float32)
    counts = RNG.integers(0, 8, size=(n, q)).astype(dtype)
    got = ops.counting_spmm(jnp.array(adj), jnp.array(counts, np.float32))
    want = ref.counting_spmm_ref(jnp.array(adj),
                                 jnp.array(counts, np.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_bfs_dense_matches_edge_relax():
    from repro.core import erdos_renyi
    from repro.core.bfs import bfs_edge_relax
    g = erdos_renyi(150, 3.0, seed=2)
    A = np.full((g.n, g.n), INF, np.float32)
    A[g.esrc, g.edst] = 1.0
    for k in (2, 5):
        dd = np.asarray(ops.bfs_dense(jnp.array(A), 0, k, inf=INF))
        de = np.asarray(bfs_edge_relax(jnp.array(g.esrc), jnp.array(g.edst),
                                       g.n, k, jnp.int32(0), jnp.int32(-1)))
        same = np.minimum(dd, k + 1) == np.minimum(de, k + 1)
        assert np.all(same | ((dd >= k + 1) & (de >= k + 1)))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,H,Hkv,D", [
    (128, 4, 4, 64), (256, 8, 4, 64), (256, 8, 2, 32), (128, 8, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(L, H, Hkv, D, dtype):
    B = 2
    q = jax.random.normal(jax.random.PRNGKey(0), (B, L, H, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, L, Hkv, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, L, Hkv, D), dtype)
    got = ops.flash_attention(q, k, v, causal=True, bq=128, bk=128)
    want = ref.mha_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_window(window):
    B, L, H, D = 1, 256, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(3), (B, L, H, D))
    k = jax.random.normal(jax.random.PRNGKey(4), (B, L, H, D))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, L, H, D))
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              bq=128, bk=128)
    want = ref.mha_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_ragged_fallback():
    B, L, H, D = 1, 100, 4, 32   # non-tile-aligned -> padded/fallback paths
    q = jax.random.normal(jax.random.PRNGKey(6), (B, L, H, D))
    k = jax.random.normal(jax.random.PRNGKey(7), (B, L, H, D))
    v = jax.random.normal(jax.random.PRNGKey(8), (B, L, H, D))
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,Hkv,D", [
    (512, 8, 2, 64), (1024, 8, 8, 32), (512, 16, 1, 64), (777, 4, 2, 32),
])
def test_decode_attention_sweep(S, H, Hkv, D):
    B = 3
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H, D))
    kc = jax.random.normal(jax.random.PRNGKey(10), (B, S, Hkv, D))
    vc = jax.random.normal(jax.random.PRNGKey(11), (B, S, Hkv, D))
    lens = jnp.array([S, max(1, S // 2), 3], jnp.int32)
    got = ops.decode_attention(q, kc, vc, lens, bs=256)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_raw_kernels_require_alignment():
    with pytest.raises(AssertionError):
        raw_minplus(jnp.zeros((100, 100)), jnp.zeros((100,)), inf=INF,
                    interpret=True)
    with pytest.raises(AssertionError):
        raw_counting(jnp.zeros((100, 100)), jnp.zeros((100, 4)),
                     interpret=True)
