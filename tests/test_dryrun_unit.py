"""Dry-run machinery unit tests (parser, specs) — the full 512-device runs
live in launch/dryrun.py and their outputs in experiments/dryrun/."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, get_shape
from repro.launch import specs as specs_mod
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import HARDWARE


HLO_SAMPLE = """
  %all-gather = f32[4096,256]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  %all-reduce.1 = bf16[256,4096]{1,0} all-reduce(%dot.1), channel_id=2, replica_groups=[16,16]<=[256], to_apply=%add
  %rs = f32[128]{0} reduce-scatter(%y), channel_id=3, replica_groups=[1,4]<=[4], dimensions={0}
  %cp = u32[64]{0} collective-permute(%z), channel_id=4, source_target_pairs={{0,1}}
  %ard = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), replica_groups={{0,1,2,3}}, to_apply=%add
"""


def test_collective_parser_kinds_and_sizes():
    out = collective_bytes(HLO_SAMPLE)
    # all-gather: result 4096*256*4 bytes, group 16 -> operand = /16
    assert out["all-gather"] == 4096 * 256 * 4 / 16
    # all-reduce: operand == result (plus the tuple one: 2*8*4 bytes)
    assert out["all-reduce"] == 256 * 4096 * 2 + 2 * 8 * 4
    # reduce-scatter: operand = result * group(4)
    assert out["reduce-scatter"] == 128 * 4 * 4
    assert out["collective-permute"] == 64 * 4
    assert out["total_operand"] == sum(
        v for k, v in out.items() if k not in ("total_operand", "wire_bytes"))
    assert out["wire_bytes"] > 0


def test_collective_parser_ignores_done_ops():
    txt = "%ag-done = f32[8]{0} all-gather-done(%ag-start)"
    out = collective_bytes(txt)
    assert out["total_operand"] == 0


@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k"])
def test_input_specs_shapes(shape_name):
    cfg = get_arch("llama3p2_1b")
    shape = get_shape(shape_name)
    sp = specs_mod.input_specs(cfg, shape)
    if shape.kind == "train":
        assert sp["batch"]["tokens"].shape == (shape.global_batch,
                                               shape.seq_len)
        assert sp["batch"]["labels"].dtype == jnp.int32
    elif shape.kind == "prefill":
        assert "labels" not in sp["batch"]
    else:
        assert sp["token"].shape == (shape.global_batch,)
        kv = [l for l in _leaves(sp["cache"]) if l.ndim == 5]
        assert kv, "decode cache must contain stacked kv tensors"
        assert kv[0].shape[3] == cfg.kv_heads


def _leaves(tree):
    import jax
    return jax.tree.leaves(tree)


def test_frontend_archs_get_prefix_embeddings():
    cfg = get_arch("phi3_vision_4p2b")
    sp = specs_mod.input_specs(cfg, get_shape("train_4k"))
    assert sp["batch"]["prefix_emb"].shape == (256, cfg.frontend_len,
                                               cfg.d_model)


def test_long500k_gates():
    for arch, expect in [("mamba2_780m", True), ("recurrentgemma_9b", True),
                         ("mistral_large_123b", False),
                         ("musicgen_large", False)]:
        cfg = get_arch(arch)
        ok, reason = cfg.shape_supported(get_shape("long_500k"))
        assert ok == expect, (arch, reason)


def test_hardware_constants_present():
    assert HARDWARE["peak_flops_bf16"] == 197e12
    assert HARDWARE["hbm_bandwidth"] == 819e9
    assert HARDWARE["ici_bandwidth"] == 50e9


def test_collective_parser_tuple_with_index_comments():
    """Tuple result types carry /*index=N*/ comments past element 4 — the
    exact formatting that silently zeroed the parser twice during bring-up."""
    line = ("  %all-reduce.1 = (f32[], f32[1024,256]{1,0}, f32[256]{0}, "
            "f32[2,256,128]{2,1,0}, f32[2,256,256]{2,1,0}, "
            "/*index=5*/f32[2,256,256]{2,1,0}, f32[2,256,128]{2,1,0}) "
            "all-reduce(%a, %b, %c, %d, %e, %f, %g), "
            "replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add")
    out = collective_bytes(line)
    want = 4 * (1 + 1024 * 256 + 256 + 2 * 256 * 128 + 2 * 256 * 256
                + 2 * 256 * 256 + 2 * 256 * 128)
    assert out["all-reduce"] == want
    assert out["wire_bytes"] == 2 * want * 7 / 8


def test_collective_parser_shardmap_psum_line():
    line = ("%psum.7 = f32[8,128]{1,0} all-reduce(%param.1), channel_id=1, "
            "replica_groups={{0,1,2,3,4,5,6,7}}, use_global_device_ids=true, "
            "to_apply=%region_0.0")
    out = collective_bytes(line)
    assert out["all-reduce"] == 8 * 128 * 4
